"""Inject the generated roofline tables and §Perf iteration log into
EXPERIMENTS.md (replaces the <!-- ROOFLINE_TABLES --> / <!-- PERF_SECTION -->
markers).

    PYTHONPATH=src python scripts/finalize_experiments.py
"""

import glob
import io
import json
import sys
from contextlib import redirect_stdout

sys.path.insert(0, "scripts")
import make_experiments  # noqa: E402


def rec(path):
    return json.load(open(path))[0]


def cell(d, arch, shape):
    for f in glob.glob(d + "/*.json"):
        for r in json.load(open(f)):
            if r["arch"] == arch and r["shape"] == shape \
                    and r["status"] == "ok":
                return r
    raise KeyError((d, arch, shape))


def row(r, label):
    ro = r["roofline"]
    peak = r["memory"]["peak_bytes_est"] / 2**30
    return (f"| {label} | {ro['compute_s']:.3g} | {ro['memory_s']:.3g} | "
            f"{ro['collective_s']:.3g} | {ro['dominant']} | "
            f"{ro['useful_flops_ratio']*100:.1f} | "
            f"{ro['roofline_mfu']*100:.2f}% | {peak:.1f} |")


HEAD = ("| config | C (s) | M (s) | N (s) | dominant | useful% | MFU bound "
        "| peak GiB/dev |\n|---|---|---|---|---|---|---|---|")


def perf_section():
    out = []
    A0 = cell("results/sweep_sp_cascade", "mistral-large-123b", "train_4k")
    A1 = rec("results/hillclimb/A1_megatron_headrepeat.json")
    A2 = rec("results/hillclimb/A2_megatron_sp.json")
    A3 = rec("results/hillclimb/A3_sp_microbatch4.json")
    B0 = cell("results/sweep_sp_cascade", "kimi-k2-1t-a32b", "train_4k")
    B1 = rec("results/hillclimb/B1_megatron_headrepeat.json")
    B2 = rec("results/hillclimb/B2_megatron_moegroups.json")
    B3 = rec("results/hillclimb/B3_sp_moegroups.json")
    B4 = rec("results/hillclimb/B4_sp_groups_mb4.json")
    C0 = cell("results/sweep_sp_cascade", "yi-6b", "decode_32k")
    C1 = rec("results/hillclimb/C1_megatron_headrepeat.json")
    C2 = rec("results/hillclimb/C2_megatron_sp_seqcache.json")

    def mfu(r):
        return r["roofline"]["roofline_mfu"] * 100

    out.append(f"""### Cell A — mistral-large-123b × train_4k (worst roofline fraction)

{HEAD}
{row(A0, "A0 cascade (paper-faithful)")}
{row(A1, "A1 megatron TP (iter 0+1)")}
{row(A2, "A2 + sequence parallel")}
{row(A3, "A3 + microbatch=4")}

* **Iter 0 (cascade→megatron).** Hypothesis: the cascade's per-linear psum
  + replicated activations waste ≥10× compute on a switched fabric; pairing
  column/row linears removes one reduction per pair and shards attention
  heads. Measured: useful FLOPs {A0['roofline']['useful_flops_ratio']*100:.1f}%→{A1['roofline']['useful_flops_ratio']*100:.1f}%, MFU bound
  {mfu(A0):.1f}%→{mfu(A1):.1f}%. **Confirmed** (the single largest step in the log).
* **Iter 1 (GQA head-repeat).** Hypothesis: reshaping q to [B,S,KV,G,hd]
  breaks head sharding (96 = 8kv×12g, neither divides TP=16), replicating
  score compute. Measured: memory term UNCHANGED — **refuted**; GSPMD had
  recovered batch sharding for the scores. (Kept anyway: required by the
  cell-C cache layout, and strictly no worse here.) Deeper HLO attribution
  instead exposed two analyzer artifacts (fused DUS / cast-chain
  accounting) that were fixed before any numbers in this file were final.
* **Iter 2 (sequence parallel).** Napkin math: the layer scan saves
  88 × [16,4096,12288] bf16 residuals ≈ 143 GB/dev (plus XLA's hoisted fp32
  copy); sharding the residual stream over TP=16 cuts both ~16×. Measured:
  peak {A1['memory']['peak_bytes_est']/2**30:.0f}→{A2['memory']['peak_bytes_est']/2**30:.0f} GiB/dev, M {A1['roofline']['memory_s']:.3g}→{A2['roofline']['memory_s']:.3g} s. **Confirmed** for memory;
  the bound shifts to collectives (SP all-gathers), MFU bound {mfu(A1):.1f}%→{mfu(A2):.1f}%.
  A1 is the throughput config; A2 the fit config.
* **Iter 3 (microbatch=4).** Hypothesis: ÷4 activation memory at ~equal
  collectives. Measured: peak {A2['memory']['peak_bytes_est']/2**30:.0f}→{A3['memory']['peak_bytes_est']/2**30:.0f} GiB ✓ but N {A2['roofline']['collective_s']:.3g}→{A3['roofline']['collective_s']:.3g} s —
  **partially refuted**: FSDP re-gathers weights and re-reduces grads per
  microbatch. Lesson recorded: under FSDP, accumulate only as much as the
  fit requires (nmb=2), or cache gathered weights across microbatches.
* **Remaining headroom.** M is {A1['roofline']['memory_s']:.3g} s at the A1 point; HLO attribution
  shows ~19% is softmax(QK) block traffic — the Pallas flash kernel
  (validated, deployment-only) removes most of it; the rest is residual/
  norm fp32 traffic inflated by CPU bf16 legalization (upper bound).

### Cell B — kimi-k2-1t-a32b × train_4k (most collective-bound)

{HEAD}
{row(B0, "B0 cascade (paper-faithful)")}
{row(B1, "B1 megatron TP")}
{row(B2, "B2 + grouped MoE dispatch")}
{row(B3, "B3 + sequence parallel")}
{row(B4, "B4 + microbatch=4")}

* **Iter 0.** As cell A: useful {B0['roofline']['useful_flops_ratio']*100:.1f}%→{B1['roofline']['useful_flops_ratio']*100:.1f}%, but the cell stays
  collective-bound: N = {B1['roofline']['collective_s']:.3g} s, {B1['roofline']['per_collective_bytes'].get('all-reduce',0)/1e12:.1f} TB all-reduce +
  {B1['roofline']['per_collective_bytes'].get('all-gather',0)/1e12:.1f} TB all-gather per device-step.
* **Iter 3 (group-limited MoE dispatch).** Hypothesis: the faithful
  global-sort dispatch gathers every token across the mesh per MoE layer
  (61 layers × ~15 GB activations); routing within data-axis groups keeps
  dispatch local, leaving only expert-weight FSDP gathers + one output
  psum — napkin estimate ~4× less wire. Measured: N {B1['roofline']['collective_s']:.3g}→{B2['roofline']['collective_s']:.3g} s
  (all-reduce {B1['roofline']['per_collective_bytes'].get('all-reduce',0)/1e12:.1f}→{B2['roofline']['per_collective_bytes'].get('all-reduce',0)/1e12:.1f} TB), MFU bound {mfu(B1):.1f}%→{mfu(B2):.1f}%. **Confirmed** —
  routing math is bit-identical at ample capacity (unit-tested).
* **Iter 2' (SP).** M {B2['roofline']['memory_s']:.3g}→{B3['roofline']['memory_s']:.3g} s, peak {B2['memory']['peak_bytes_est']/2**30:.0f}→{B3['memory']['peak_bytes_est']/2**30:.0f} GiB. Bound ~flat
  ({mfu(B2):.1f}%→{mfu(B3):.1f}%): kimi's memory is dispatch buffers, not residuals —
  **partially confirmed** (fit yes, bound no).
* **Iter 4 (microbatch).** Peak ~unchanged ({B3['memory']['peak_bytes_est']/2**30:.0f}→{B4['memory']['peak_bytes_est']/2**30:.0f} GiB): MoE transients
  dominate, and collectives double — **refuted** for this cell.
* **Fit honesty:** single-pod kimi train cannot fit regardless (bf16
  params+grads ≈ 16 GB/chip); from 2 pods (512 chips) params 4 + grads 4 +
  Adafactor ~2 GB ✓. The multi-pod dry-run pass compiles exactly that
  config. Remaining N = {B3['roofline']['collective_s']:.3g} s is expert-weight FSDP gathers —
  next lever: 2D expert sharding or gather/compute overlap (future work).

### Cell C — yi-6b × decode_32k (the paper's GEMV serving regime)

{HEAD}
{row(C0, "C0 cascade (paper-faithful)")}
{row(C1, "C1 megatron TP")}
{row(C2, "C2 + seq-sharded KV cache")}

* **Iter 0.** Cascade decode psums every layer's full activation per
  token: N = {C0['roofline']['collective_s']:.3g} s per token — hopeless. Megatron TP: N {C0['roofline']['collective_s']:.3g}→{C1['roofline']['collective_s']:.3g} s.
* **Iter 4 (flash-decoding cache layout).** Hypothesis: with the cache
  sharded on head_dim, the q·k contraction is sharded → a
  [B,H,1,S] ≈ 0.5 GB score psum per layer ({C1['roofline']['per_collective_bytes'].get('all-gather',0)/1e9:.0f} GB/step measured as
  cache gathers); sharding the cache on SEQUENCE makes scores local and
  only softmax stats + [B,1,H,hd] partials cross the wire (~2 MB/layer).
  Measured: N {C1['roofline']['collective_s']*1e3:.0f}→{C2['roofline']['collective_s']*1e3:.0f} ms per token — **confirmed, {C1['roofline']['collective_s']/max(C2['roofline']['collective_s'],1e-9):.0f}×**. The
  GSPMD partitioner materializes the distributed softmax automatically
  from the sharding constraint.
* **Step bound now {C2['roofline']['step_time_bound_s']*1e3:.0f} ms/token (memory-dominant).** Attribution: ~⅔ of
  the remaining M is the CPU backend's hoisted fp32 copy of the bf16
  cache (no TPU analogue — native bf16 MXU); adjusted per-token bound
  ≈ {(C2['roofline']['memory_s']/3 + 0.002)*1e3:.0f} ms ≈ cache+weights streaming at HBM rate, i.e. the true
  roofline for batch-128 decode of a 6B model on 256 chips. Next lever:
  int8 KV cache (halves cache traffic; the paper's own quantization story
  applied to serving state — future work).
""")
    return "\n".join(out)


def main():
    buf = io.StringIO()
    with redirect_stdout(buf):
        make_experiments.main()
    tables = buf.getvalue()

    text = open("EXPERIMENTS.md").read()
    text = text.replace("<!-- ROOFLINE_TABLES -->", tables)
    text = text.replace("<!-- PERF_SECTION -->", perf_section())
    open("EXPERIMENTS.md", "w").write(text)
    print("EXPERIMENTS.md finalized "
          f"({len(text.splitlines())} lines)")


if __name__ == "__main__":
    main()
